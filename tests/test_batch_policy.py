"""Heterogeneous per-worker batch sizes: simulation coupling, the bucketed
masked executor path (one trace per ladder rung), fixed-policy bitwise
parity, staleness/variance trade-off, and per-worker RNG attribution."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import samplers
from repro.cluster import (
    ClusterEngine,
    WorkerSchedule,
    ensemble_async,
)
from repro.core import Quadratic, WorkerModel, simulate_async, truncate_to_evals
from repro.samplers.transform import chain, stateless

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# simulation: batch sizes coupled to drawn compute times
# ---------------------------------------------------------------------------
def test_fixed_batch_policy_leaves_realized_trace_unchanged():
    """The fixed policy scales step times by exactly 1.0 and consumes the
    same RNG stream, so delays / times / worker ids match the legacy
    simulation bitwise; only the batch_sizes annotation is new."""
    wm = WorkerModel(num_workers=6, seed=2)
    legacy = simulate_async(wm, 500, seed=4)
    fixed = simulate_async(wm, 500, seed=4, batch_policy="fixed",
                           base_batch=32)
    np.testing.assert_array_equal(legacy.delays, fixed.delays)
    np.testing.assert_array_equal(legacy.commit_times, fixed.commit_times)
    np.testing.assert_array_equal(legacy.worker_ids, fixed.worker_ids)
    assert np.all(fixed.batch_sizes == 32)
    assert fixed.total_grad_evals == 500 * 32


def test_inverse_speed_couples_batch_to_worker_speed():
    """Slow workers must draw strictly larger (bucket-snapped) batches than
    the fastest worker, and every commit's size must be its worker's size."""
    wm = WorkerModel(num_workers=8, heterogeneity=0.6, seed=3)
    sizes = wm.batch_sizes("inverse-speed", base_batch=8)
    fastest = int(np.argmin(wm._speeds))
    slowest = int(np.argmax(wm._speeds))
    assert sizes[fastest] == 8  # relative speed 1 -> base, already a rung
    assert sizes[slowest] > sizes[fastest]
    assert all(b & (b - 1) == 0 for b in sizes)  # pow2 ladder
    tr = simulate_async(wm, 300, seed=0, batch_policy="inverse-speed",
                        base_batch=8)
    np.testing.assert_array_equal(tr.batch_sizes, sizes[tr.worker_ids])


def test_inverse_speed_amortizes_commit_overhead():
    """At an equal gradient-evaluation budget, inverse-speed batching must
    finish in less simulated wall clock than fixed batching: every worker's
    per-example time is identical, but larger batches pay the serialized
    update cost less often."""
    wm = WorkerModel(num_workers=8, heterogeneity=0.6, update_cost=0.2,
                     seed=5)
    fixed = simulate_async(wm, 400, seed=6, batch_policy="fixed",
                           base_batch=8)
    budget = fixed.total_grad_evals
    het = truncate_to_evals(
        simulate_async(wm, 400, seed=6, batch_policy="inverse-speed",
                       base_batch=8), budget)
    assert het.total_grad_evals >= budget
    assert het.commit_times[-1] < fixed.commit_times[-1]


def test_truncate_to_evals_prefix_semantics():
    wm = WorkerModel(num_workers=4, heterogeneity=0.5, seed=1)
    tr = simulate_async(wm, 100, seed=2, batch_policy="inverse-speed",
                        base_batch=4)
    cut = truncate_to_evals(tr, 57)
    k = len(cut.delays)
    assert cut.batch_sizes[:k - 1].sum() < 57 <= cut.total_grad_evals
    np.testing.assert_array_equal(cut.delays, tr.delays[:k])
    with pytest.raises(ValueError, match="grad evals"):
        truncate_to_evals(cut, 10**9)


# ---------------------------------------------------------------------------
# schedule compilation
# ---------------------------------------------------------------------------
def test_schedule_carries_sizes_offsets_and_slots():
    wm = WorkerModel(num_workers=3, heterogeneity=0.5, seed=0)
    sched = WorkerSchedule.from_trace(
        simulate_async(wm, 50, seed=1, batch_policy="inverse-speed",
                       base_batch=4))
    offs = sched.data_offsets
    np.testing.assert_array_equal(
        offs, np.concatenate([[0], np.cumsum(sched.batch_sizes[:-1])]))
    np.testing.assert_array_equal(sched.grad_evals,
                                  np.cumsum(sched.batch_sizes))
    # worker-local slots count each worker's commits in order
    slots = sched.worker_slots
    for w in range(3):
        mine = slots[sched.worker_ids == w]
        np.testing.assert_array_equal(mine, np.arange(len(mine)))


def test_with_batch_sizes_snaps_up_the_ladder():
    sched = WorkerSchedule.sync(6).with_batch_sizes(
        np.array([1, 3, 5, 8, 9, 2]))
    np.testing.assert_array_equal(sched.batch_sizes, [1, 4, 8, 8, 16, 2])
    explicit = WorkerSchedule.sync(3).with_batch_sizes(
        np.array([3, 5, 9]), buckets=(4, 16))
    np.testing.assert_array_equal(explicit.batch_sizes, [4, 16, 16])


# ---------------------------------------------------------------------------
# executor: masked bucket-padded path
# ---------------------------------------------------------------------------
C, D, B0 = 4, 3, 4


@pytest.fixture(scope="module")
def quad():
    return Quadratic.make(jax.random.PRNGKey(0), d=D, m=1.0, L=3.0)


def _per_example(quad):
    return lambda p, e: quad.grad(p, None) + 0.3 * e


def _dense_mean_oracle(per_ex):
    return lambda p, batch: jnp.mean(
        jax.vmap(lambda e: per_ex(p, e))(batch), axis=0)


def test_masked_step_at_full_bucket_is_bitwise_dense(quad):
    """One vmapped commit through the masked chain at exactly the base
    bucket must equal the dense fixed-shape chain bit for bit: an all-ones
    mask multiplies by 1.0, masked_mean reduces like jnp.mean, and the
    gamma scale is exactly 1.0.  (Whole-trajectory comparisons across the
    two *programs* are checked to tolerance below — the gather in the
    masked scan changes XLA fusion, which is allowed to differ in the last
    ulp between programs.)"""
    from repro.cluster.ensemble import ensemble_step, init_ensemble
    from repro.samplers.transforms import MaskedBatch

    per_ex = _per_example(quad)
    batch = jax.random.normal(jax.random.PRNGKey(7), (C, B0, D))
    key = jax.random.PRNGKey(42)
    delay = jnp.zeros(C, jnp.int32)

    dense_sampler = samplers.sgld("consistent", _dense_mean_oracle(per_ex),
                                  gamma=0.02, sigma=0.5, tau=8)
    masked_sampler = samplers.sgld("consistent", per_ex, gamma=0.02,
                                   sigma=0.5, tau=8, base_batch=B0)
    s1 = init_ensemble(dense_sampler, jnp.ones(D), key, num_chains=C)
    s2 = init_ensemble(masked_sampler, jnp.ones(D), key, num_chains=C)
    f1 = jax.jit(ensemble_step(dense_sampler, batch_axis=0))
    f2 = jax.jit(ensemble_step(masked_sampler, batch_axis=0))
    for _ in range(3):
        s1, _ = f1(s1, batch, delay)
        s2, _ = f2(s2, MaskedBatch(batch, jnp.full(C, B0, jnp.int32)), delay)
        assert np.array_equal(np.asarray(s1.params), np.asarray(s2.params))


def test_masked_trajectory_at_full_bucket_matches_dense_engine(quad):
    """batch_policy="explicit" with every commit at the base bucket must
    reproduce the legacy per-chain-batches engine trajectory (same
    schedules, same keys, same data rows) to float tolerance."""
    steps = 12
    per_ex = _per_example(quad)
    data = np.asarray(jax.random.normal(jax.random.PRNGKey(7),
                                        (steps * B0, D)), np.float32)
    scheds = ensemble_async(WorkerModel(num_workers=4, seed=1), steps, C,
                            seed=0)
    key = jax.random.PRNGKey(42)

    dense_sampler = samplers.sgld("consistent", _dense_mean_oracle(per_ex),
                                  gamma=0.02, sigma=0.5, tau=8)
    per_chain = np.broadcast_to(data.reshape(steps, 1, B0, D),
                                (steps, C, B0, D)).copy()
    dense = ClusterEngine(dense_sampler, num_chains=C, chunk_size=6,
                          per_chain_batches=True)
    s_dense = dense.init(jnp.zeros(D), key)
    s_dense, _ = dense.run(s_dense, steps=steps, schedule=scheds,
                           batches=jnp.asarray(per_chain))

    masked_sampler = samplers.sgld("consistent", per_ex, gamma=0.02,
                                   sigma=0.5, tau=8, base_batch=B0)
    masked = ClusterEngine(masked_sampler, num_chains=C, chunk_size=6,
                           batch_policy="explicit")
    s_mask = masked.init(jnp.zeros(D), key)
    s_mask, _ = masked.run(s_mask, steps=steps, schedule=scheds, data=data,
                           batch_sizes=np.full(steps, B0))
    np.testing.assert_allclose(np.asarray(s_dense.params),
                               np.asarray(s_mask.params),
                               rtol=2e-6, atol=2e-7)
    np.testing.assert_array_equal(np.asarray(s_dense.step),
                                  np.asarray(s_mask.step))


def test_one_trace_per_bucket_rung_across_mixed_sizes(quad):
    """A mixed-size schedule must compile one trace per bucket-ladder rung
    its chunks touch — never one per distinct batch size."""
    per_ex = _per_example(quad)
    sampler = samplers.sgld("consistent", per_ex, gamma=0.02, sigma=0.5,
                            tau=4, base_batch=B0)
    engine = ClusterEngine(sampler, num_chains=C, chunk_size=8,
                           batch_policy="explicit")
    data = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (256, D)),
                      np.float32)
    # chunk 1 sizes {2,3,4} -> rung 4; chunk 2 {5,8} -> rung 8;
    # chunk 3 {1,7,8} -> rung 8 again (no new trace)
    sizes = np.array([2, 3, 4, 2, 2, 3, 4, 4,
                      5, 8, 5, 5, 8, 8, 5, 8,
                      1, 7, 8, 1, 1, 7, 8, 8])
    state = engine.init(jnp.zeros(D), jax.random.PRNGKey(0))
    state, _ = engine.run(state, steps=24, schedule=None, data=data,
                          batch_sizes=sizes)
    assert engine.num_traces == 2, engine.num_traces
    # a rerun with different sizes on the same rungs compiles nothing new
    state, _ = engine.run(state, steps=24, schedule=None, data=data,
                          batch_sizes=np.minimum(sizes + 1, 8))
    assert engine.num_traces == 2, engine.num_traces
    assert np.all(np.isfinite(np.asarray(state.params)))


def test_masked_run_threads_grad_evals_and_commit_times(quad):
    per_ex = _per_example(quad)
    wm = WorkerModel(num_workers=4, heterogeneity=0.6, seed=0)
    scheds = ensemble_async(wm, 20, C, seed=0,
                            batch_policy="inverse-speed", base_batch=B0)
    tau = max(s.max_delay for s in scheds)
    sampler = samplers.sgld("consistent", per_ex, gamma=0.02, sigma=0.5,
                            tau=max(tau, 1), base_batch=B0)
    engine = ClusterEngine(sampler, num_chains=C, chunk_size=10,
                           batch_policy="inverse-speed", collect_aux=True)
    data = np.asarray(jax.random.normal(jax.random.PRNGKey(5), (512, D)),
                      np.float32)
    state = engine.init(jnp.zeros(D), jax.random.PRNGKey(1))
    state, aux = engine.run(state, steps=20, schedule=scheds, data=data)
    evals = np.asarray(aux["grad_evals"])
    assert evals.shape == (20, C)
    np.testing.assert_array_equal(
        evals[:, 0], np.cumsum(scheds[0].batch_sizes[:20]))
    assert aux["commit_time"].shape == (20, C)


def test_inverse_speed_lowers_slow_worker_commit_variance(quad):
    """The staleness/variance trade (Chen et al.): a slow worker's committed
    gradient, averaged over its inverse-speed batch, must have markedly
    lower variance than a fast worker's small-batch commit."""
    steps = 400
    wm = WorkerModel(num_workers=4, heterogeneity=0.6, seed=0)
    sizes = wm.batch_sizes("inverse-speed", base_batch=B0)
    slowest, fastest = int(np.argmax(sizes)), int(np.argmin(sizes))
    assert sizes[slowest] >= 4 * sizes[fastest]
    sched = WorkerSchedule.from_trace(
        simulate_async(wm, steps, seed=2, batch_policy="inverse-speed",
                       base_batch=B0))
    # pure-noise oracle: the committed "gradient" is the masked mean of the
    # example rows, surfaced via aux; gamma=0 freezes the chains
    noise_only = samplers.Sampler(
        chain(samplers.masked_gradients(lambda p, e: (e, e), has_aux=True),
              samplers.apply_sgld_update()),
        gamma=0.0)
    engine = ClusterEngine(noise_only, num_chains=2, chunk_size=100,
                           batch_policy="inverse-speed", collect_aux=True)
    data = np.asarray(jax.random.normal(jax.random.PRNGKey(11),
                                        (8192, D)), np.float32)
    state = engine.init(jnp.zeros(D), jax.random.PRNGKey(3))
    state, aux = engine.run(state, steps=steps, schedule=sched, data=data)
    committed = np.asarray(aux["aux"])[:, 0, :]  # (steps, D), chain 0
    var_slow = committed[sched.worker_ids == slowest].var()
    var_fast = committed[sched.worker_ids == fastest].var()
    assert var_slow < 0.6 * var_fast, (var_slow, var_fast)
    # and quantitatively ~ sigma^2 / batch
    ratio = var_fast / var_slow
    expect = sizes[slowest] / sizes[fastest]
    assert 0.5 * expect < ratio < 2.0 * expect, (ratio, expect)


def test_heterogeneous_policy_validation(quad):
    per_ex = _per_example(quad)
    sampler = samplers.sgld("sync", per_ex, gamma=0.01, sigma=0.5,
                            base_batch=B0)
    with pytest.raises(ValueError, match="unknown batch_policy"):
        ClusterEngine(sampler, num_chains=2, batch_policy="bogus")
    with pytest.raises(ValueError, match="batch_fn generates fixed-shape"):
        ClusterEngine(sampler, num_chains=2, batch_policy="inverse-speed",
                      batch_fn=lambda k: jnp.zeros(3))
    engine = ClusterEngine(sampler, num_chains=2,
                           batch_policy="inverse-speed")
    state = engine.init(jnp.zeros(D), jax.random.PRNGKey(0))
    data = np.zeros((16, D), np.float32)
    with pytest.raises(ValueError, match="needs a data="):
        engine.run(state, steps=4, schedule=None)
    with pytest.raises(ValueError, match="carrying"):
        # schedules without batch_sizes can't drive the inverse-speed policy
        engine.run(state, steps=4,
                   schedule=WorkerSchedule.from_delays(np.zeros(4, np.int64)),
                   data=data)
    explicit = ClusterEngine(sampler, num_chains=2, batch_policy="explicit")
    with pytest.raises(ValueError, match="batch_sizes="):
        explicit.run(state, steps=4, schedule=None, data=data)


# ---------------------------------------------------------------------------
# per-worker RNG attribution
# ---------------------------------------------------------------------------
def _noise_recorder_sampler(sigma=0.5):
    """Zero-gradient SGLD that surfaces each commit's injected noise in aux."""
    record = stateless(lambda ctx: ctx._replace(aux=ctx.noise))
    return samplers.Sampler(
        chain(samplers.gradients(lambda p, b: jnp.zeros_like(p)),
              samplers.langevin_noise(sigma), record,
              samplers.apply_sgld_update()),
        gamma=0.01)


def _sync_schedule_with_workers(worker_ids):
    n = len(worker_ids)
    return WorkerSchedule(
        read_versions=np.arange(n, dtype=np.int32),
        worker_ids=np.asarray(worker_ids, np.int32),
        commit_times=np.arange(1, n + 1, dtype=np.float64),
        num_workers=int(np.max(worker_ids)) + 1)


def test_worker_rng_noise_stream_invariant_under_commit_permutation():
    """With worker_rng, a commit's noise is keyed on (chain key, worker id,
    worker-local slot): permuting the global commit order permutes the
    draws with it, so each worker's noise stream is reproducible
    independently of how the simulator interleaved the workers."""
    order_a = [0, 0, 1, 1, 0, 1, 2, 2]
    order_b = [2, 1, 0, 1, 0, 2, 0, 1]  # same per-worker commit counts
    noises = {}
    for name, order in (("a", order_a), ("b", order_b)):
        engine = ClusterEngine(_noise_recorder_sampler(), num_chains=2,
                               chunk_size=4, worker_rng=True,
                               collect_aux=True)
        state = engine.init(jnp.zeros(D), jax.random.PRNGKey(9))
        state, aux = engine.run(state, steps=len(order),
                                schedule=_sync_schedule_with_workers(order))
        noises[name] = np.asarray(aux["aux"] if isinstance(aux, dict)
                                  else aux)
    sched_a = _sync_schedule_with_workers(order_a)
    sched_b = _sync_schedule_with_workers(order_b)
    key_a = list(zip(sched_a.worker_ids.tolist(),
                     sched_a.worker_slots.tolist()))
    key_b = list(zip(sched_b.worker_ids.tolist(),
                     sched_b.worker_slots.tolist()))
    for wk in set(key_a):
        ia, ib = key_a.index(wk), key_b.index(wk)
        np.testing.assert_array_equal(noises["a"][ia], noises["b"][ib]), wk
    # and the attributed stream is genuinely per-worker: distinct draws
    assert not np.array_equal(noises["a"][0], noises["a"][1])


def test_worker_rng_continuation_draws_fresh_noise():
    """Worker slots are rebased by the state's commit counter on a
    continuation run (like read versions), so resuming with the same
    schedule folds fresh (wid, slot) pairs instead of replaying the first
    run's noise stream."""
    order = [0, 1, 0, 1]
    engine = ClusterEngine(_noise_recorder_sampler(), num_chains=2,
                           chunk_size=4, worker_rng=True, collect_aux=True)
    state = engine.init(jnp.zeros(D), jax.random.PRNGKey(9))
    sched = _sync_schedule_with_workers(order)
    state, aux1 = engine.run(state, steps=len(order), schedule=sched)
    state, aux2 = engine.run(state, steps=len(order), schedule=sched)
    n1, n2 = np.asarray(aux1["aux"]), np.asarray(aux2["aux"])
    assert not np.array_equal(n1, n2)
    # no single commit's draw is repeated either
    flat1 = {n1[i].tobytes() for i in range(len(order))}
    flat2 = {n2[i].tobytes() for i in range(len(order))}
    assert not (flat1 & flat2)


def test_worker_rng_off_keeps_sequential_stream(quad):
    """worker_rng=False must stay bit-identical to the pre-attribution
    executor: the same run with and without worker metadata in the
    schedule agrees exactly (pinned against the single-chain parity suite
    elsewhere)."""
    scheds = ensemble_async(WorkerModel(num_workers=4, seed=1), 15, C, seed=0)
    sampler = samplers.sgld("consistent", lambda p, b: quad.grad(p, b),
                            gamma=0.01, sigma=0.5, tau=8)
    outs = []
    for worker_rng in (False, False):
        engine = ClusterEngine(sampler, num_chains=C, chunk_size=5,
                               worker_rng=worker_rng)
        state = engine.init(jnp.zeros(D), jax.random.PRNGKey(4))
        state, _ = engine.run(state, steps=15, schedule=scheds)
        outs.append(np.asarray(state.params))
    assert np.array_equal(outs[0], outs[1])
    # attribution changes the stream (it is a different, documented contract)
    engine = ClusterEngine(sampler, num_chains=C, chunk_size=5,
                           worker_rng=True)
    state = engine.init(jnp.zeros(D), jax.random.PRNGKey(4))
    state, _ = engine.run(state, steps=15, schedule=scheds)
    assert not np.array_equal(outs[0], np.asarray(state.params))


# ---------------------------------------------------------------------------
# sharded equivalence of the masked path (subprocess: 8 forced host devices)
# ---------------------------------------------------------------------------
SCRIPT_SHARDED_MASKED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp, numpy as np
from repro import samplers
from repro.cluster import ClusterEngine, ensemble_async
from repro.core import Quadratic, WorkerModel
from repro.launch.mesh import make_debug_mesh

quad = Quadratic.make(jax.random.PRNGKey(0), d=3, m=1.0, L=3.0)
per_ex = lambda p, e: quad.grad(p, None) + 0.3 * e
C, steps, b0 = 8, 20, 4
wm = WorkerModel(num_workers=4, heterogeneity=0.6, seed=1)
scheds = ensemble_async(wm, steps, C, seed=0,
                        batch_policy="inverse-speed", base_batch=b0)
tau = max(s.max_delay for s in scheds)
sampler = samplers.sgld("consistent", per_ex, gamma=0.01, sigma=0.5,
                        tau=max(tau, 1), base_batch=b0)
data = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (512, 3)),
                  np.float32)
key = jax.random.PRNGKey(42)

local = ClusterEngine(sampler, num_chains=C, chunk_size=10,
                      batch_policy="inverse-speed")
s_local = local.init(jnp.zeros(3), key)
s_local, _ = local.run(s_local, steps=steps, schedule=scheds, data=data)

mesh = make_debug_mesh(data=2, model=2)
sharded = ClusterEngine(sampler, num_chains=C, chunk_size=10,
                        batch_policy="inverse-speed", mesh=mesh)
s_shard = sharded.init(jnp.zeros(3), key)
s_shard, _ = sharded.run(s_shard, steps=steps, schedule=scheds, data=data)

print(json.dumps({
    "bitwise_equal": bool(np.array_equal(np.asarray(s_local.params),
                                         np.asarray(s_shard.params))),
    "traces_match": sharded.num_traces == local.num_traces,
}))
"""


@pytest.mark.slow
def test_sharded_masked_matches_unsharded_on_debug_mesh():
    from subproc import run_json

    res = run_json(SCRIPT_SHARDED_MASKED, timeout=600)
    assert res["bitwise_equal"], res
    assert res["traces_match"], res

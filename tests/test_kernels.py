"""Pallas kernels vs pure-jnp oracles (interpret mode), hypothesis sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import langevin_update as lu
from repro.kernels.ops import (
    delay_gather_flat,
    fused_delay_gather,
    fused_langevin_update,
    langevin_update_flat,
)
from repro.kernels.ref import delay_gather_ref, langevin_update_ref
from repro.kernels.rng import normal_from_counter, threefry2x32
from repro.utils import round_up


# ---------------------------------------------------------------------------
# RNG building block
# ---------------------------------------------------------------------------
def test_threefry_reference_vector():
    """Threefry2x32 known-answer test (Random123 test vector, zeros)."""
    x0, x1 = threefry2x32(jnp.uint32(0), jnp.uint32(0),
                          jnp.uint32(0), jnp.uint32(0))
    assert (int(x0), int(x1)) == (0x6B200159, 0x99BA4EFE)


def test_normal_statistics():
    counter = jnp.arange(1 << 18, dtype=jnp.uint32)
    z = np.asarray(normal_from_counter(jnp.uint32(7), jnp.uint32(9), counter))
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    assert abs((z**3).mean()) < 0.03  # skew
    assert abs((z**4).mean() - 3.0) < 0.1  # kurtosis


def test_rng_deterministic_and_seed_sensitive():
    c = jnp.arange(4096, dtype=jnp.uint32)
    a = normal_from_counter(jnp.uint32(1), jnp.uint32(2), c)
    b = normal_from_counter(jnp.uint32(1), jnp.uint32(2), c)
    d = normal_from_counter(jnp.uint32(1), jnp.uint32(3), c)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.abs(np.asarray(a) - np.asarray(d)).max() > 0.1


# ---------------------------------------------------------------------------
# langevin_update kernel
# ---------------------------------------------------------------------------
@given(n=st.integers(1, 5_000_00), gamma=st.floats(1e-5, 0.5),
       scale=st.floats(0.0, 1.0))
@settings(max_examples=10, deadline=None)
def test_langevin_kernel_vs_ref(n, gamma, scale):
    key = jax.random.PRNGKey(n % 17)
    x = jax.random.normal(key, (n,))
    g = jax.random.normal(jax.random.PRNGKey(1), (n,))
    seed = jnp.array([n % 251, 77], jnp.uint32)
    got = langevin_update_flat(x, g, seed, gamma, scale)
    rows = round_up(-(-n // lu.LANES), lu.BLOCK_ROWS)
    pad = rows * lu.LANES
    xp = jnp.zeros((pad,)).at[:n].set(x).reshape(rows, lu.LANES)
    gp = jnp.zeros((pad,)).at[:n].set(g).reshape(rows, lu.LANES)
    want = langevin_update_ref(xp, gp, seed, gamma, scale).reshape(-1)[:n]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_langevin_kernel_dtypes(dtype):
    n = 3000
    x = jnp.ones((n,), dtype)
    g = jnp.ones((n,), dtype)
    out = langevin_update_flat(x, g, jnp.array([0, 0], jnp.uint32), 0.5, 0.0)
    np.testing.assert_allclose(np.asarray(out, np.float32), 0.5, rtol=1e-2)
    assert out.dtype == dtype


def test_fused_tree_update_noise_statistics():
    params = {"a": jnp.zeros((200, 700)), "b": jnp.zeros((999,))}
    grads = jax.tree_util.tree_map(jnp.zeros_like, params)
    out = fused_langevin_update(params, grads, jnp.array([5, 6], jnp.uint32),
                                0.0, 1.0)
    z = np.concatenate([np.asarray(x).ravel() for x in
                        jax.tree_util.tree_leaves(out)])
    assert abs(z.mean()) < 0.02 and abs(z.std() - 1.0) < 0.02
    # distinct leaves get distinct noise
    assert np.abs(np.asarray(out["a"]).ravel()[:999]
                  - np.asarray(out["b"])).max() > 0.1


# ---------------------------------------------------------------------------
# delay_gather kernel
# ---------------------------------------------------------------------------
@given(depth=st.integers(1, 9), n=st.integers(1, 20_000), seed=st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_delay_gather_vs_ref(depth, n, seed):
    h = jax.random.normal(jax.random.PRNGKey(seed), (depth, n))
    slots = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, depth)
    got = delay_gather_flat(h, slots)
    want = delay_gather_ref(h, slots)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_delay_gather_dtypes(dtype):
    h = jnp.arange(4 * 5000).reshape(4, 5000).astype(dtype)
    slots = jnp.tile(jnp.arange(4, dtype=jnp.int32), 1250)
    got = delay_gather_flat(h, slots)
    want = delay_gather_ref(h, slots)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_delay_gather_matches_ring_semantics():
    from repro.core import init_ring, push, read_inconsistent

    params = {"w": jnp.zeros((64, 33))}
    ring = init_ring(params, tau=3)
    for k in range(1, 6):
        ring = push(ring, {"w": jnp.full((64, 33), float(k))})
    delays = {"w": jax.random.randint(jax.random.PRNGKey(0), (64, 33), 0, 4)}
    want = read_inconsistent(ring, delays)
    got = fused_delay_gather(ring.history, delays, ring.head, ring.depth)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(want["w"]))

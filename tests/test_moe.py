"""MoE routing / dispatch correctness (local path; sharded path covered by
test_sharding subprocess tests)."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.moe import _moe_local, apply_moe, capacity, init_moe
from repro.models.common import activation


@pytest.fixture(scope="module")
def cfg():
    return replace(get_reduced("phi3.5-moe-42b-a6.6b"), dtype="float32")


def test_top1_routing_selects_expert(cfg):
    """With a hand-built router, tokens go to the intended expert."""
    cfg1 = replace(cfg, experts_per_token=1, num_experts=4)
    p = init_moe(jax.random.PRNGKey(0), cfg1, jnp.float32)
    d = cfg1.d_model
    # router that routes by sign pattern of first feature
    router = jnp.zeros((d, 4)).at[0, 0].set(10.0).at[0, 1].set(-10.0)
    p = dict(p, router=router)
    xt = jnp.zeros((8, d)).at[:4, 0].set(1.0).at[4:, 0].set(-1.0)
    out, aux = _moe_local(p, xt, cfg1, 4, 0, capacity(8, cfg1),
                          activation(cfg1.act))
    # expert 0 processes tokens 0..3, expert 1 tokens 4..7: outputs within
    # each group identical, across groups different
    o = np.asarray(out)
    np.testing.assert_allclose(o[0], o[1], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(o[4], o[5], rtol=1e-5, atol=1e-6)
    assert np.abs(o[0] - o[4]).max() > 1e-4


def test_capacity_drop(cfg):
    """Tokens beyond expert capacity are dropped, not mis-routed."""
    cfg1 = replace(cfg, experts_per_token=1, num_experts=4)
    p = init_moe(jax.random.PRNGKey(1), cfg1, jnp.float32)
    d = cfg1.d_model
    router = jnp.zeros((d, 4)).at[0, 0].set(10.0)  # everything -> expert 0
    p = dict(p, router=router)
    xt = jnp.ones((32, d))
    cap = 4
    out, _ = _moe_local(p, xt, cfg1, 4, 0, cap, activation(cfg1.act))
    o = np.asarray(out)
    # exactly cap tokens processed; the rest got zero contribution
    nonzero = (np.abs(o).max(axis=1) > 1e-7).sum()
    assert nonzero == cap


def test_aux_loss_uniform_router_is_one(cfg):
    """Switch aux loss == 1 for a perfectly uniform router."""
    cfg1 = replace(cfg, num_experts=4, experts_per_token=1)
    p = init_moe(jax.random.PRNGKey(2), cfg1, jnp.float32)
    p = dict(p, router=jnp.zeros((cfg1.d_model, 4)))
    # logits all equal -> probs uniform; top-1 ties broken by index (all to
    # expert 0) -> aux = E * (1 * 1/E) = 1 for probs, frac_tokens=e0=1:
    # aux = E * sum(frac_tokens * frac_probs) = 4 * (1*0.25) = 1
    xt = jax.random.normal(jax.random.PRNGKey(3), (64, cfg1.d_model)) * 0.0
    _, aux = _moe_local(p, xt, cfg1, 4, 0, capacity(64, cfg1),
                        activation(cfg1.act))
    assert float(aux) == pytest.approx(1.0, rel=1e-3)


def test_moe_apply_differentiable(cfg):
    cfg1 = replace(cfg, num_experts=4, experts_per_token=2)
    p = init_moe(jax.random.PRNGKey(4), cfg1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg1.d_model))

    def loss(p):
        y, aux = apply_moe(p, x, cfg1, mesh=None)
        return jnp.sum(y**2) + aux

    g = jax.grad(loss)(p)
    gn = sum(float(jnp.sum(v**2)) for v in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0
    # router must receive gradient (through combine weights)
    assert float(jnp.sum(g["router"]**2)) > 0


def test_shared_expert_contributes(cfg):
    cfg1 = replace(cfg, num_experts=4, experts_per_token=2,
                   num_shared_experts=1)
    p = init_moe(jax.random.PRNGKey(6), cfg1, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 4, cfg1.d_model))
    y1, _ = apply_moe(p, x, cfg1, mesh=None)
    p2 = dict(p, shared_w_down=jnp.zeros_like(p["shared_w_down"]))
    y2, _ = apply_moe(p2, x, cfg1, mesh=None)
    assert float(jnp.abs(y1 - y2).max()) > 1e-5

"""SSD (mamba-2) and xLSTM blocks: chunk invariance + decode==parallel."""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.ssm import apply_ssm, init_ssm, init_ssm_state
from repro.models.xlstm import (
    apply_mlstm,
    apply_slstm,
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
)


@pytest.fixture(scope="module")
def hymba_cfg():
    return replace(get_reduced("hymba-1.5b"), dtype="float32")


@pytest.fixture(scope="module")
def xlstm_cfg():
    return replace(get_reduced("xlstm-1.3b"), dtype="float32")


def test_ssd_chunk_invariance(hymba_cfg):
    cfg = hymba_cfg
    p = init_ssm(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    outs = [np.asarray(apply_ssm(p, x, cfg, chunk=c)) for c in (8, 16, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=1e-4, rtol=1e-3)


def test_ssd_decode_equals_parallel(hymba_cfg):
    cfg = hymba_cfg
    p = init_ssm(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model))
    y_par = apply_ssm(p, x, cfg, chunk=32)
    st = init_ssm_state(cfg, 2)
    ys = []
    for t in range(32):
        yt, st = apply_ssm(p, x[:, t:t + 1], cfg, state=st)
        ys.append(yt)
    y_seq = jnp.concatenate(ys, 1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-3)


def test_ssd_state_decays(hymba_cfg):
    """With zero input the SSM state must decay (A < 0): contribution of an
    impulse vanishes over time — the sub-quadratic long-context claim."""
    cfg = hymba_cfg
    p = init_ssm(jax.random.PRNGKey(5), cfg, jnp.float32)
    st = init_ssm_state(cfg, 1)
    x_impulse = jnp.ones((1, 1, cfg.d_model))
    _, st = apply_ssm(p, x_impulse, cfg, state=st)
    h0 = float(jnp.abs(st.h).max())
    x_zero = jnp.zeros((1, 1, cfg.d_model))
    for _ in range(200):
        _, st = apply_ssm(p, x_zero, cfg, state=st)
    h1 = float(jnp.abs(st.h).max())
    assert h1 < h0


def test_mlstm_chunk_invariance_and_decode(xlstm_cfg):
    cfg = xlstm_cfg
    p = init_mlstm(jax.random.PRNGKey(6), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(7), (2, 64, cfg.d_model))
    y64 = apply_mlstm(p, x, cfg, chunk=64)
    y8 = apply_mlstm(p, x, cfg, chunk=8)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y8),
                               atol=1e-4, rtol=1e-3)
    st = init_mlstm_state(cfg, 2)
    ys = []
    for t in range(64):
        yt, st = apply_mlstm(p, x[:, t:t + 1], cfg, state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y64), atol=1e-4, rtol=1e-3)


def test_slstm_decode_equals_scan(xlstm_cfg):
    cfg = xlstm_cfg
    p = init_slstm(jax.random.PRNGKey(8), cfg, jnp.float32)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(9), (2, 32, cfg.d_model))
    y_full = apply_slstm(p, x, cfg)
    st = init_slstm_state(cfg, 2)
    ys = []
    for t in range(32):
        yt, st = apply_slstm(p, x[:, t:t + 1], cfg, state=st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)


def test_slstm_exponential_gating_stable():
    """Large gate pre-activations must not overflow (stabilizer m)."""
    cfg = replace(get_reduced("xlstm-1.3b"), dtype="float32")
    p = init_slstm(jax.random.PRNGKey(10), cfg, jnp.float32)
    x = 30.0 * jax.random.normal(jax.random.PRNGKey(11), (1, 64, cfg.d_model))
    y = apply_slstm(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
